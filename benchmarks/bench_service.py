"""Meili-Serve resource-efficiency benchmark (ISSUE 2/3; paper §8, Fig 13).

Runs the default 6-tenant mix through the deployment-mode comparator
(pooled vs standalone vs microservice) under the bursty and diurnal
scenarios, with one NIC failure injected into the pooled bursty run, plus
the churn-heavy defragmentation A/B (ISSUE 3) and the QoS records
(ISSUE 4): the flash-crowd isolation A/B (ResourceGovernor on vs off, same
mix and seeded traffic on a headroom-free pool) and the adversarial-churn
admission-pressure run. Writes ``BENCH_service.json`` with the efficiency
ratios, per-scenario per-tenant SLO compliance, the failover record, the
locality-recovery record, and the isolation record.

Headline acceptance bars (checked by ``main`` and surfaced in the JSON):
  pooled efficiency >= 2x standalone, >= 1.2x microservice, all tenant SLOs
  pass under both scenarios, the injected failure drops no tenant,
  defrag-on uses fewer NICs with fewer hop-penalty pairs than defrag-off
  with no tenant SLO regression, governor-on keeps every in-quota tenant
  within SLO under the flash crowd while governor-off breaks >= 1, and
  adversarial churn rejects strictly without harming admitted tenants.

Run headlessly:   PYTHONPATH=src python -m benchmarks.bench_service
Smoke (CI) mode:  PYTHONPATH=src python -m benchmarks.bench_service --fast
Defrag A/B only:  PYTHONPATH=src python -m benchmarks.bench_service --scenario churn
QoS A/B only:     PYTHONPATH=src python -m benchmarks.bench_service --scenario flashcrowd
                  (+ --scenario adversarial; both via make bench-qos)
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import pathlib
import statistics
import tempfile
import time

from benchmarks.common import row
from repro.core.controller import MeiliController
from repro.obs.runlog import RunLogger
from repro.core.faults import (FLAP, GRAY, MID_MIGRATION, RACK, REVIVE,
                               ChaosEngine, FaultEvent, FaultPlan,
                               RecoveryConfig)
from repro.core.pool import paper_cluster
from repro.core.qos import ResourceGovernor
from repro.service.efficiency import MODES, run_comparison
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (TenantRegistry, churn_tenant_mix,
                                   contracts, default_tenant_mix)
from repro.service.workload import make_scenario

TICKS = 120
FAST_TICKS = 32
CHURN_TICKS = 96
CHURN_FAST_TICKS = 48
QOS_TICKS = 96
QOS_FAST_TICKS = 48
CHAOS_TICKS = 110
CHAOS_FAST_TICKS = 48

# The chaos A/B runs on a 2-rack 8-NIC pool: rack1 (half of every NIC
# class) is the correlated-outage domain, rack0 hosts the gray failure.
CHAOS_POOL = dict(n_bf2=4, n_bf1=2, n_pensando=2, racks=2)
CHAOS_RACK = "rack1"

# The QoS isolation A/B runs on a pool with no multiplexing headroom (the
# flash-crowd premise): a 6-NIC rack that admits the 6-tenant mix at
# contract with little slack. The crowd is the heaviest per-Gbps consumer
# (FW: 3.75 Gbps per unit, CPU-only — the axis every tenant shares).
QOS_POOL = dict(n_bf2=3, n_bf1=1, n_pensando=2)
QOS_CROWD = "t-fw"
QOS_SURGE = 8.0

BARS = {"pooled_vs_standalone": 2.0, "pooled_vs_microservice": 1.2}

# SLO/alerting/flight overhead bar (ISSUE 10): the always-on budget scoring
# + per-tick burn-rule evaluation + flight-ring snapshots may cost at most
# this fraction of wall-clock on the fast chaos scenario. The gated number
# is measured IN-RUN (the layer's entry points are timed inside the arm
# that runs them, divided by the same run's wall) because cross-run A/B on
# this class of shared host has a null floor wider than the bar itself:
# two IDENTICAL baseline arms, interleaved and min-filtered over 9 reps,
# still read each other as +/-4-6 pct. See run_slo's docstring.
SLO_OVERHEAD_MAX = 0.05
# The A/B arms still run (aliveness, mitigation behavior, and the reported
# raw wall ratio), advanced interleaved SLO_CHUNK ticks at a time so every
# arm samples every noise regime the run drifts through, repeated SLO_REPS
# times.
SLO_REPS = 5
SLO_CHUNK = 32


def run(emit=print, fast: bool = False, seed: int = 0,
        scenario: str = "full", obs_dir=None) -> dict:
    if scenario == "churn":
        res = {"defrag": run_defrag(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["defrag"]["pass"]
        return res
    if scenario == "flashcrowd":
        res = {"qos": run_qos(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["qos"]["pass"]
        return res
    if scenario == "adversarial":
        res = {"adversarial_churn": run_adversarial(emit=emit, fast=fast,
                                                    seed=seed)}
        res["pass"] = res["adversarial_churn"]["pass"]
        return res
    if scenario == "chaos":
        res = {"chaos": run_chaos(emit=emit, fast=fast, seed=seed,
                                  obs_dir=obs_dir)}
        res["pass"] = res["chaos"]["pass"]
        return res
    if scenario == "slo":
        res = {"slo": run_slo(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["slo"]["pass"]
        return res
    cfg = RuntimeConfig() if not fast else RuntimeConfig(
        dataplane_every=0, max_sim_seqs=48)
    res = run_comparison(ticks=FAST_TICKS if fast else TICKS, cfg=cfg,
                         seed=seed)
    for mode in MODES:
        emit(row(f"service_eff_{mode}", 0,
                 f"{res['efficiency'][mode]:.3f}Gbps_per_unit"))
    for name, ratio in res["ratios"].items():
        emit(row(f"service_{name}", 0,
                 f"{ratio:.2f}x_bar{BARS[name]:.1f}x"))
    for scenario, rec in res["scenarios"].items():
        for mode in MODES:
            emit(row(f"service_slo_{scenario}_{mode}", 0,
                     f"pass={rec[mode]['slo_pass']}"))
        if "failover" in rec:
            fo = rec["failover"]
            emit(row(f"service_failover_{scenario}", 0,
                     f"nic={fo['failed_nic']}_alive={fo['tenants_alive_after']}"
                     f"_survived={fo['survived']}"))
    res["defrag"] = run_defrag(emit=emit, fast=fast, seed=seed)
    res["qos"] = run_qos(emit=emit, fast=fast, seed=seed)
    res["adversarial_churn"] = run_adversarial(emit=emit, fast=fast,
                                               seed=seed)
    res["chaos"] = run_chaos(emit=emit, fast=fast, seed=seed,
                             obs_dir=obs_dir)
    res["slo"] = run_slo(emit=emit, fast=fast, seed=seed)
    res["bars"] = BARS
    res["pass"] = check(res)
    return res


def _run_churn_arm(defrag_on: bool, ticks: int, cfg: RuntimeConfig,
                   seed: int) -> dict:
    """One arm of the defrag A/B: same mix, same seeded traffic; only the
    background re-placement loop differs."""
    cfg = dataclasses.replace(
        cfg, defrag_every=8 if defrag_on else 0, defrag_max_moves=2)
    mix = churn_tenant_mix(ticks=ticks)
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("churn", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()     # churn + migration must leave pool truth intact
    slo = rt.slo_report()
    # Score locality over the settled tail of the run — after both churn
    # waves have landed, where fragmentation (or its recovery) persists.
    loc = rt.telemetry.locality(from_tick=int(0.7 * ticks))
    return {
        "locality": loc,
        "slo": slo,
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
        "migrations": sum(1 for e in ctrl.events if e["event"] == "migrate"),
        "alive_tenants": len(rt.alive_tenants()),
    }


def run_defrag(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Churn-heavy locality decay and recovery (ISSUE 3 acceptance).

    The full run drives the fused data plane like every other full-mode
    scenario; ``--fast`` drops to the analytic model only."""
    ticks = CHURN_FAST_TICKS if fast else CHURN_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    off = _run_churn_arm(False, ticks, cfg, seed)
    on = _run_churn_arm(True, ticks, cfg, seed)
    # No-regression: every tenant that passed its SLO with defrag off must
    # still pass with defrag on.
    regressed = sorted(t for t, ok in off["slo_pass"].items()
                       if ok and not on["slo_pass"].get(t, False))
    rec = {
        # self-describing: this record can be merged into a JSON produced
        # by a different mode/seed (--scenario churn), so it carries its own
        # run metadata rather than inheriting the file's.
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "defrag_off": off,
        "defrag_on": on,
        "recovery": {
            "nics_used_mean_delta": (off["locality"]["nics_used_mean"]
                                     - on["locality"]["nics_used_mean"]),
            "hop_pairs_mean_delta": (off["locality"]["hop_pairs_mean"]
                                     - on["locality"]["hop_pairs_mean"]),
            "slo_regressions": regressed,
        },
    }
    rec["pass"] = (rec["recovery"]["nics_used_mean_delta"] > 0.0
                   and rec["recovery"]["hop_pairs_mean_delta"] > 0.0
                   and not regressed
                   and on["migrations"] > 0)
    emit(row("service_defrag_nics", 0,
             f"{off['locality']['nics_used_mean']:.2f}_to_"
             f"{on['locality']['nics_used_mean']:.2f}"))
    emit(row("service_defrag_hop_pairs", 0,
             f"{off['locality']['hop_pairs_mean']:.2f}_to_"
             f"{on['locality']['hop_pairs_mean']:.2f}"))
    emit(row("service_defrag_migrations", 0, f"{on['migrations']}moves"))
    emit(row("service_defrag", 0, f"pass={rec['pass']}"))
    return rec


def _qos_mix():
    """The evaluation mix without backup NICs (the QoS pool is smaller than
    the full rack, so the default bf1 backups may not exist)."""
    return [dataclasses.replace(s, backup_nic=None)
            for s in default_tenant_mix()]


def _run_flash_arm(governor_on: bool, ticks: int, cfg: RuntimeConfig,
                   seed: int) -> dict:
    """One arm of the QoS isolation A/B: same mix, same seeded flash-crowd
    traffic; only quota enforcement differs (ResourceGovernor enabled/off)."""
    mix = _qos_mix()
    ctrl = MeiliController(paper_cluster(**QOS_POOL),
                           governor=ResourceGovernor(enabled=governor_on))
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("flash_crowd", contracts(mix), seed=seed,
                       surge=QOS_SURGE, crowd=QOS_CROWD)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()     # quota enforcement must leave pool truth intact
    slo = rt.slo_report()
    crowd_ticks = rt.telemetry.series(QOS_CROWD)
    return {
        "slo": slo,
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
        "crowd_peak_granted_gbps": max(
            (t.granted_gbps for t in crowd_ticks), default=0.0),
        "crowd_peak_backlog_pkts": max(
            (t.backlog_pkts for t in crowd_ticks), default=0.0),
        "alive_tenants": len(rt.alive_tenants()),
    }


def run_qos(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Flash-crowd isolation A/B (ISSUE 4 acceptance): with the governor, a
    crowd tenant exceeding its quota queues behind its own deficit and
    degrades only itself; without it, the crowd's unguarded over-scaling
    strips the headroom ≥1 in-quota tenant needs and breaks its SLO."""
    ticks = QOS_FAST_TICKS if fast else QOS_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    on = _run_flash_arm(True, ticks, cfg, seed)
    off = _run_flash_arm(False, ticks, cfg, seed)
    innocents_on_ok = all(ok for t, ok in on["slo_pass"].items()
                          if t != QOS_CROWD)
    broken_off = sorted(t for t, ok in off["slo_pass"].items()
                        if t != QOS_CROWD and not ok)
    crowd_quota = contracts(_qos_mix())[QOS_CROWD]   # default quota = contract
    crowd_clamped = on["crowd_peak_granted_gbps"] <= crowd_quota + 1e-6
    rec = {
        # self-describing (mergeable into a JSON from another mode/seed).
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(QOS_POOL),
        "crowd": QOS_CROWD,
        "surge": QOS_SURGE,
        "governor_on": on,
        "governor_off": off,
        "isolation": {
            "innocents_within_slo_on": innocents_on_ok,
            "crowd_clamped_at_quota_on": crowd_clamped,
            "crowd_contained_on": not on["slo_pass"].get(QOS_CROWD, True),
            "innocents_broken_off": broken_off,
        },
    }
    # Pass: governor-on protects every in-quota tenant AND actually clamps
    # the crowd at its quota (its excess degrades only itself), while
    # governor-off demonstrably harms >= 1 innocent.
    rec["pass"] = bool(innocents_on_ok and crowd_clamped and broken_off)
    emit(row("service_qos_crowd_granted", 0,
             f"on{on['crowd_peak_granted_gbps']:.1f}Gbps_off"
             f"{off['crowd_peak_granted_gbps']:.1f}Gbps"))
    emit(row("service_qos_isolation_on", 0,
             f"innocents_ok={innocents_on_ok}"))
    emit(row("service_qos_isolation_off", 0,
             f"broken={len(broken_off)}:{','.join(broken_off) or 'none'}"))
    emit(row("service_qos", 0, f"pass={rec['pass']}"))
    return rec


def run_adversarial(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Adversarial churn (admission pressure at peak): the churning tenant
    mix under correlated near-contract load on the headroom-free QoS pool —
    wave-2 arrivals must be strictly admitted (or rejected) while the pool
    is as full as it gets, without harming anyone already admitted."""
    ticks = QOS_FAST_TICKS if fast else QOS_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    mix = [dataclasses.replace(s, backup_nic=None)
           for s in churn_tenant_mix(ticks=ticks)]
    ctrl = MeiliController(paper_cluster(**QOS_POOL),
                           governor=ResourceGovernor())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("adversarial_churn", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()
    slo = rt.slo_report()
    rec = {
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(QOS_POOL),
        "admitted": len(registry.admitted),
        "rejected": {t: r for t, r in registry.rejected.items()},
        "alive_tenants": len(rt.alive_tenants()),
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
    }
    # Pass: admission pressure was real (>=1 strict rejection), nobody
    # admitted was dropped, no admitted tenant lost its SLO, ledger exact.
    rec["pass"] = bool(rec["rejected"]
                       and rec["alive_tenants"] == rec["admitted"]
                       and all(rec["slo_pass"].values()))
    emit(row("service_adversarial_admissions", 0,
             f"admitted{rec['admitted']}_rejected{len(rec['rejected'])}"))
    emit(row("service_adversarial_churn", 0, f"pass={rec['pass']}"))
    return rec


def _chaos_mix():
    """The evaluation mix with backups remapped onto the chaos pool's two
    BF-1s (the default mix names bf1-2/bf1-3, which do not exist here)."""
    backups = ("bf1-0", "bf1-1")
    return [dataclasses.replace(s, backup_nic=backups[i % len(backups)])
            for i, s in enumerate(default_tenant_mix())]


def _chaos_plan(ticks: int, flap_nic: str, gray_nic: str) -> FaultPlan:
    """The compound fault sequence, identical on both arms: an early link
    flap, a silent gray degradation on a busy surviving-rack NIC, a crash
    landed inside a make-before-break migration window, a correlated rack
    outage taking half the pool, and a late repair wave that ends the
    incident — every NIC still down (the rack, the gray NIC, and whichever
    NIC the mid-migration crash hit) is replaced."""
    T = ticks
    return FaultPlan([
        FaultEvent(tick=max(2, int(0.11 * T)), kind=FLAP, nic=flap_nic,
                   duration_ticks=max(2, T // 16)),
        FaultEvent(tick=int(0.28 * T), kind=GRAY, nic=gray_nic,
                   fraction=0.25),
        FaultEvent(tick=int(0.44 * T), kind=MID_MIGRATION),
        FaultEvent(tick=int(0.55 * T), kind=RACK, rack=CHAOS_RACK),
        FaultEvent(tick=int(0.72 * T), kind=REVIVE),
    ])


def _run_chaos_arm(recovery_on: bool, ticks: int, seed: int,
                   obs_dir=None) -> dict:
    """One arm of the chaos A/B: same mix, same seeded traffic, same fault
    plan; only the recovery policy differs. ON = park + backoff re-admission
    + brownout partial grants + gray-failure detection; OFF = the legacy
    eviction-or-nothing baseline with no detection. With ``obs_dir`` set
    the arm's observability context (decision-audit trace + metrics) is
    dumped under ``<obs_dir>/chaos_{on,off}/`` as a run artifact."""
    cfg = RuntimeConfig(dataplane_every=0, max_sim_seqs=48,
                        gray_detect=recovery_on)
    mix = _chaos_mix()
    ctrl = MeiliController(paper_cluster(**CHAOS_POOL))
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("chaos", contracts(mix), seed=seed)
    rec_cfg = (RecoveryConfig(park=True, brownout=True, seed=seed)
               if recovery_on else RecoveryConfig(park=False, brownout=False))
    rt = ServiceRuntime(ctrl, registry, wl, cfg, recovery=rec_cfg)
    registry.admit_all()
    # Fault targets from the deterministic initial placement (identical on
    # both arms): the flap hits the busiest NIC overall, the gray failure
    # the busiest *surviving-rack* NIC that is not the flap target — so the
    # gray NIC carries tenants whose achieved throughput can betray it.
    usage: dict = {}
    for dep in ctrl.deployments.values():
        for n, nic_row in dep.allocation.A.items():
            usage[n] = usage.get(n, 0) + sum(nic_row.values())
    flap_nic = max(usage, key=lambda n: (usage[n], n))
    rack0 = [n for n in ctrl.pool.rack_members("rack0") if n != flap_nic]
    gray_nic = max(rack0, key=lambda n: (usage.get(n, 0), n))
    engine = ChaosEngine(_chaos_plan(ticks, flap_nic, gray_nic))
    rt.run(ticks, chaos=engine)
    ctrl.check_ledger()     # the sentinel also ran after every fault
    tele = rt.telemetry
    artifacts = None
    if obs_dir is not None:
        rt.obs.snapshot_compile_caches(planes=rt._planes.values())
        arm_dir = (pathlib.Path(obs_dir)
                   / ("chaos_on" if recovery_on else "chaos_off"))
        artifacts = rt.obs.dump(arm_dir)
    return {
        "recovery_on": recovery_on,
        "flap_nic": flap_nic,
        "gray_nic": gray_nic,
        "slo_ticks": tele.slo_tick_count(cfg.warmup_ticks),
        # Measured p99 (obs histogram over the run's sample stream) beside
        # the per-tick legacy estimator's max.
        "p99_measured_s_max": max(
            (t.p99_measured_s for t in tele.tenant_ticks), default=0.0),
        "p99_legacy_s_max": max(
            (t.p99_s for t in tele.tenant_ticks), default=0.0),
        "obs_artifacts": artifacts,
        "permanent_evictions": sorted(set(rt.recovery.evicted)),
        "parked_events": len(tele.faults("parked")),
        "readmissions": len(rt.recovery.readmissions),
        "still_parked": sorted(rt.recovery.parked),
        "mttr_ticks": rt.recovery.mean_time_to_recover(),
        "brownout_ticks": len({f.tick for f in tele.faults("degraded")}),
        "gray_probations": sorted({f.nic for f in
                                   tele.faults("gray_probation")}),
        "faults_injected": len(engine.fired),
        "alive_tenants": len(rt.alive_tenants()),
        "ledger_clean": True,
    }


def run_chaos(emit=print, fast: bool = False, seed: int = 0,
              obs_dir=None) -> dict:
    """Chaos fault-injection A/B (ISSUE 6 acceptance): under an identical
    compound fault plan, recovery-on must strictly dominate recovery-off —
    more tenant-ticks of SLO-compliant service, fewer permanent evictions
    (off must demonstrably lose >= 1 tenant for good), and a finite mean
    time-to-recover with every parked tenant re-admitted by run end. The
    invariant sentinel validates the ledger after every injected fault."""
    ticks = CHAOS_FAST_TICKS if fast else CHAOS_TICKS
    on = _run_chaos_arm(True, ticks, seed, obs_dir=obs_dir)
    off = _run_chaos_arm(False, ticks, seed, obs_dir=obs_dir)
    rec = {
        # self-describing (mergeable into a JSON from another mode/seed).
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(CHAOS_POOL),
        "recovery_on": on,
        "recovery_off": off,
        "dominance": {
            "slo_ticks_on_vs_off": [on["slo_ticks"], off["slo_ticks"]],
            "permanent_evictions_on_vs_off": [
                len(on["permanent_evictions"]),
                len(off["permanent_evictions"])],
            "all_parked_readmitted_on": not on["still_parked"],
            "mttr_ticks_on": on["mttr_ticks"],
        },
    }
    recovered = (not on["still_parked"]
                 and (on["parked_events"] == 0
                      or on["mttr_ticks"] is not None))
    rec["pass"] = bool(
        on["slo_ticks"] > off["slo_ticks"]
        and len(on["permanent_evictions"]) < len(off["permanent_evictions"])
        and off["permanent_evictions"]
        and recovered)
    emit(row("service_chaos_slo_ticks", 0,
             f"on{on['slo_ticks']}_off{off['slo_ticks']}"))
    emit(row("service_chaos_evictions", 0,
             f"on{len(on['permanent_evictions'])}"
             f"_off{len(off['permanent_evictions'])}"))
    emit(row("service_chaos_recovery", 0,
             f"parked{on['parked_events']}_readmitted{on['readmissions']}"
             f"_mttr{on['mttr_ticks'] if on['mttr_ticks'] is not None else 'na'}"))
    emit(row("service_chaos_brownout", 0,
             f"{on['brownout_ticks']}ticks_gray="
             f"{','.join(on['gray_probations']) or 'none'}"))
    emit(row("service_chaos_p99", 0,
             f"measured{on['p99_measured_s_max'] * 1e3:.1f}ms_legacy"
             f"{on['p99_legacy_s_max'] * 1e3:.1f}ms"))
    emit(row("service_chaos", 0, f"pass={rec['pass']}"))
    return rec


def _slo_arm_setup(slo_on: bool, ticks: int, seed: int,
                   flight_dir=None, alert_actions: bool = True):
    """Build one arm of the SLO-overhead A/B (not yet run): the fast chaos
    scenario (recovery + gray detection on, identical mix/traffic/fault
    plan) with the SLO engine + burn-rate alerting + flight recorder ON or
    OFF. ``alert_actions=False`` is shadow mode: alerts fire/trace/dump
    but pages take no mitigation action. Returns (runtime, chaos_engine)
    ready for ``rt.run(n, chaos=engine)``."""
    cfg = RuntimeConfig(dataplane_every=0, max_sim_seqs=48, gray_detect=True,
                        slo_enabled=slo_on, flight_dir=flight_dir,
                        alert_actions=alert_actions)
    mix = _chaos_mix()
    ctrl = MeiliController(paper_cluster(**CHAOS_POOL))
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("chaos", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg,
                        recovery=RecoveryConfig(park=True, brownout=True,
                                                seed=seed))
    registry.admit_all()
    usage: dict = {}
    for dep in ctrl.deployments.values():
        for n, nic_row in dep.allocation.A.items():
            usage[n] = usage.get(n, 0) + sum(nic_row.values())
    flap_nic = max(usage, key=lambda n: (usage[n], n))
    rack0 = [n for n in ctrl.pool.rack_members("rack0") if n != flap_nic]
    gray_nic = max(rack0, key=lambda n: (usage.get(n, 0), n))
    engine = ChaosEngine(_chaos_plan(ticks, flap_nic, gray_nic))
    return rt, engine


def _instrument_slo(rt) -> dict:
    """Wrap the four SLO-layer entry points on a live runtime with
    wall-clock accumulators (budget scoring, burn-rule evaluation,
    flight-ring snapshot, incident dump). Every call the layer makes is
    timed — including the wrapper's own perf_counter pair, which counts
    AGAINST the layer, so the attribution is conservative. Returns the
    accumulator dict (component -> seconds, mutated in place)."""
    acc = {"slo_observe": 0.0, "alerts_step": 0.0,
           "flight_snapshot": 0.0, "flight_dump": 0.0}

    def wrap(obj, name, key):
        fn = getattr(obj, name)

        def timed(*a, **k):
            t0 = time.perf_counter()
            try:
                return fn(*a, **k)
            finally:
                acc[key] += time.perf_counter() - t0
        setattr(obj, name, timed)

    wrap(rt.slo, "observe", "slo_observe")
    wrap(rt.alerts, "step", "alerts_step")
    wrap(rt.flight, "snapshot", "flight_snapshot")
    wrap(rt.flight, "dump_safe", "flight_dump")
    return acc


def run_slo(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """SLO/alerting/flight overhead benchmark (ISSUE 10 acceptance),
    three arms on the fast chaos scenario, ``SLO_REPS`` interleaved reps:

      off     — SLO layer disabled (baseline);
      shadow  — the whole recording path ON (budget scoring every recorded
                tick, both burn rules every tick, flight-ring snapshot
                every tick, page-triggered dumps into a temp dir) but
                ``alert_actions=False``: pages take no mitigation action;
      on      — full layer, pages pre-arm the gray detector + force a
                scale consult.

    ``overhead_frac`` (gated ≤ ``SLO_OVERHEAD_MAX`` in ``check_bench``) is
    the always-on cost of *recording* — the claim the bar defends —
    measured by IN-RUN ATTRIBUTION on the shadow arm: the layer's four
    entry points (budget scoring, burn-rule evaluation, flight snapshot,
    incident dump) are wall-clock-timed inside the run, and the gated
    number is layer-time over non-layer-time, median over reps. Numerator
    and denominator come from the SAME run, so cross-run scheduler noise
    cancels exactly; the wrapper's own timer cost lands in the numerator,
    so the attribution is conservative. Reproducibility measured at
    ±0.01 percentage points across invocations.

    Why not gate the naive A/B wall ratio? It was measured unusable HERE:
    this container's noise regime drifts on the same timescale as a run
    with ~30 pct bursts, and a null experiment — two IDENTICAL off arms,
    interleaved chunks, per-round minima over 9 reps — still read
    +6.2/-2.8 pct across invocations (CPU-time variant: ±4 pct). A 5 pct
    bar cannot sit on a ±5 pct instrument. The raw interleaved A/B ratio
    is still recorded (``ab_wall_overhead_frac``) for context, unguarded.
    on vs shadow is reported as ``mitigation_cost_frac``: real
    control-plane work (earlier quarantines, forced rescales) the early
    warning buys, priced separately because billing response work as
    recording overhead would conflate the smoke detector with the fire
    brigade. The alive-ness gates (pages fired, bundles dumped) run on the
    full arm. Every arm uses the fast runtime configuration (dataplane
    off) — the harshest denominator for the bar, since a tick is pure host
    bookkeeping.

    ``fast=True`` (the ``--fast``/tier-1 smoke) runs ONE rep at 1x ticks
    and gates aliveness only; the smoke record self-describes as fast and
    ``check_bench`` skips its overhead number, exactly like the other
    fast-mode records. ``make bench-slo`` writes the measurement-grade
    record the gate scores: 4x ticks (the fault plan scales with it),
    ``SLO_REPS`` reps, arms advanced interleaved ``SLO_CHUNK`` ticks at a
    time with the within-round order rotated."""
    reps = 1 if fast else SLO_REPS
    ticks = CHAOS_FAST_TICKS if fast else CHAOS_FAST_TICKS * 4
    walls: dict = {"off": [], "shadow": [], "on": []}
    arms = ("off", "shadow", "on")
    attr_fracs: list = []       # per-rep attributed overhead, shadow arm
    comp_s: dict = {}           # component -> seconds summed over reps
    with tempfile.TemporaryDirectory(prefix="flight_bench_") as tmp:
        for rep in range(reps):
            rts = {arm: _slo_arm_setup(
                       arm != "off", ticks, seed,
                       flight_dir=tmp if arm != "off" else None,
                       alert_actions=(arm == "on"))
                   for arm in arms}
            acc = _instrument_slo(rts["shadow"][0])
            total = dict.fromkeys(arms, 0.0)
            # GC pauses land on whichever arm happens to cross a collection
            # threshold (the recording arms allocate more, so the off arm
            # would also *inherit* their debt) — collect up front and keep
            # the cycle collector out of the timed region for all arms.
            gc.collect()
            gc.disable()
            try:
                done = rnd = 0
                while done < ticks:
                    n = min(SLO_CHUNK, ticks - done)
                    for arm in arms[rnd % 3:] + arms[:rnd % 3]:
                        rt, engine = rts[arm]
                        t0 = time.perf_counter()
                        rt.run(n, chaos=engine)
                        total[arm] += time.perf_counter() - t0
                    done += n
                    rnd += 1
            finally:
                gc.enable()
            for arm in arms:
                rts[arm][0].ctrl.check_ledger()
                walls[arm].append(total[arm])
            layer = sum(acc.values())
            attr_fracs.append(layer / max(total["shadow"] - layer, 1e-9))
            for k, v in acc.items():
                comp_s[k] = comp_s.get(k, 0.0) + v
        rt_on = rts["on"][0]
        dumps = len(rt_on.flight.dumps)
        shadow_dumps = len(rts["shadow"][0].flight.dumps)
    wall_off, wall_shadow, wall_on = (statistics.median(walls[k])
                                      for k in ("off", "shadow", "on"))
    # the gated number: in-run attributed layer cost (see docstring)
    overhead = statistics.median(attr_fracs)
    # paired within-rep wall ratios: context only, never gated
    ab_overhead = statistics.median(
        s / o - 1.0 for s, o in zip(walls["shadow"], walls["off"]))
    mitigation = statistics.median(
        n / s - 1.0 for n, s in zip(walls["on"], walls["shadow"]))
    transitions = rt_on.alerts.transitions
    pages = sum(1 for t in transitions if t.severity == "page"
                and t.state == "firing")
    rec = {
        # self-describing (mergeable into a JSON from another mode/seed):
        # fast smoke records are skipped by the check_bench overhead gate.
        "fast": bool(fast),
        "seed": seed,
        "ticks": ticks,
        "reps": reps,
        "pool": dict(CHAOS_POOL),
        "wall_s_off": wall_off,
        "wall_s_shadow": wall_shadow,
        "wall_s_on": wall_on,
        "overhead_frac": overhead,
        "overhead_max": SLO_OVERHEAD_MAX,
        "overhead_components_ms": {k: round(v / reps * 1e3, 3)
                                   for k, v in sorted(comp_s.items())},
        "ab_wall_overhead_frac": ab_overhead,
        "mitigation_cost_frac": mitigation,
        "alert_transitions": len(transitions),
        "page_alerts": pages,
        "flight_dumps": dumps,
        "shadow_flight_dumps": shadow_dumps,
        "budgets_tracked": len(rt_on.slo.budgets),
    }
    # Pass: recording is cheap AND the layer is demonstrably alive under
    # chaos — the full arm must page and auto-dump at least one bundle.
    # The smoke gates aliveness only (see docstring).
    rec["pass"] = bool((fast or overhead <= SLO_OVERHEAD_MAX)
                       and pages > 0 and dumps > 0)
    emit(row("service_slo_overhead", 0,
             f"attr{overhead * 100:+.2f}pct_bar"
             f"{SLO_OVERHEAD_MAX * 100:.0f}pct_abwall"
             f"{ab_overhead * 100:+.1f}pct"))
    emit(row("service_slo_mitigation", 0,
             f"on{wall_on:.2f}s_{mitigation * 100:+.1f}pct_response_work"))
    emit(row("service_slo_alerts", 0,
             f"transitions{len(transitions)}_pages{pages}_dumps{dumps}"))
    emit(row("service_slo", 0, f"pass={rec['pass']}"))
    return rec


def check(res: dict) -> bool:
    ok = all(res["ratios"][k] >= bar for k, bar in BARS.items())
    for rec in res["scenarios"].values():
        ok = ok and all(rec[m]["slo_pass"] for m in MODES)
        if "failover" in rec:
            ok = ok and rec["failover"]["survived"]
    for extra in ("defrag", "qos", "adversarial_churn", "chaos", "slo"):
        if extra in res:
            ok = ok and res[extra]["pass"]
    return ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: fewer ticks, analytic model only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario",
                    choices=("full", "churn", "flashcrowd", "adversarial",
                             "chaos", "slo"),
                    default="full",
                    help="churn = only the defragmentation A/B "
                         "(make bench-defrag); flashcrowd = only the QoS "
                         "isolation A/B, adversarial = only the "
                         "admission-pressure run (make bench-qos); slo = "
                         "only the SLO/alerting/flight overhead A/B "
                         "(make bench-slo)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_service.json)")
    ap.add_argument("--emit-obs", action="store_true",
                    help="write observability artifacts (decision-audit "
                         "trace + metrics + structured run log) under "
                         "--obs-dir")
    ap.add_argument("--obs-dir", default="obs_artifacts",
                    help="artifact directory for --emit-obs "
                         "(default: ./obs_artifacts)")
    args = ap.parse_args(argv)

    obs_dir = args.obs_dir if args.emit_obs else None
    logger = RunLogger("bench_service", out_dir=obs_dir)
    logger.note(fast=args.fast, seed=args.seed, scenario=args.scenario)
    logger.emit("name,us_per_call,derived")
    res = run(emit=logger.emit, fast=args.fast, seed=args.seed,
              scenario=args.scenario, obs_dir=obs_dir)
    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parent.parent / "BENCH_service.json")
    payload = {
        "benchmark": "meili-serve deployment-mode comparison",
        "fast": args.fast,
        "seed": args.seed,
        "scenario": args.scenario,
        "ticks": FAST_TICKS if args.fast else TICKS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **res,
    }
    partial_keys = {"churn": "defrag", "flashcrowd": "qos", "chaos": "chaos",
                    "adversarial": "adversarial_churn", "slo": "slo"}
    if args.scenario in partial_keys:
        # keep the full-comparison numbers already on disk; merge the new
        # partial record into the existing JSON instead of clobbering it
        key = partial_keys[args.scenario]
        if out.exists():
            try:
                prev = json.loads(out.read_text())
                prev.update({key: payload[key],
                             "timestamp": payload["timestamp"]})
                if "ratios" in prev:
                    prev["pass"] = check(prev)
                payload = prev
            except (ValueError, KeyError):
                pass
    out.write_text(json.dumps(payload, indent=2) + "\n")
    logger.close()
    print(f"# wrote {out}")
    if obs_dir is not None:
        print(f"# wrote obs artifacts under {obs_dir}")
    if not res["pass"]:
        raise SystemExit("service benchmark below acceptance bars")


if __name__ == "__main__":
    main()
