"""Meili-Serve resource-efficiency benchmark (ISSUE 2/3; paper §8, Fig 13).

Runs the default 6-tenant mix through the deployment-mode comparator
(pooled vs standalone vs microservice) under the bursty and diurnal
scenarios, with one NIC failure injected into the pooled bursty run, plus
the churn-heavy defragmentation A/B (ISSUE 3) and the QoS records
(ISSUE 4): the flash-crowd isolation A/B (ResourceGovernor on vs off, same
mix and seeded traffic on a headroom-free pool) and the adversarial-churn
admission-pressure run. Writes ``BENCH_service.json`` with the efficiency
ratios, per-scenario per-tenant SLO compliance, the failover record, the
locality-recovery record, and the isolation record.

Headline acceptance bars (checked by ``main`` and surfaced in the JSON):
  pooled efficiency >= 2x standalone, >= 1.2x microservice, all tenant SLOs
  pass under both scenarios, the injected failure drops no tenant,
  defrag-on uses fewer NICs with fewer hop-penalty pairs than defrag-off
  with no tenant SLO regression, governor-on keeps every in-quota tenant
  within SLO under the flash crowd while governor-off breaks >= 1, and
  adversarial churn rejects strictly without harming admitted tenants.

Run headlessly:   PYTHONPATH=src python -m benchmarks.bench_service
Smoke (CI) mode:  PYTHONPATH=src python -m benchmarks.bench_service --fast
Defrag A/B only:  PYTHONPATH=src python -m benchmarks.bench_service --scenario churn
QoS A/B only:     PYTHONPATH=src python -m benchmarks.bench_service --scenario flashcrowd
                  (+ --scenario adversarial; both via make bench-qos)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

from benchmarks.common import row
from repro.core.controller import MeiliController
from repro.obs.runlog import RunLogger
from repro.core.faults import (FLAP, GRAY, MID_MIGRATION, RACK, REVIVE,
                               ChaosEngine, FaultEvent, FaultPlan,
                               RecoveryConfig)
from repro.core.pool import paper_cluster
from repro.core.qos import ResourceGovernor
from repro.service.efficiency import MODES, run_comparison
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (TenantRegistry, churn_tenant_mix,
                                   contracts, default_tenant_mix)
from repro.service.workload import make_scenario

TICKS = 120
FAST_TICKS = 32
CHURN_TICKS = 96
CHURN_FAST_TICKS = 48
QOS_TICKS = 96
QOS_FAST_TICKS = 48
CHAOS_TICKS = 110
CHAOS_FAST_TICKS = 48

# The chaos A/B runs on a 2-rack 8-NIC pool: rack1 (half of every NIC
# class) is the correlated-outage domain, rack0 hosts the gray failure.
CHAOS_POOL = dict(n_bf2=4, n_bf1=2, n_pensando=2, racks=2)
CHAOS_RACK = "rack1"

# The QoS isolation A/B runs on a pool with no multiplexing headroom (the
# flash-crowd premise): a 6-NIC rack that admits the 6-tenant mix at
# contract with little slack. The crowd is the heaviest per-Gbps consumer
# (FW: 3.75 Gbps per unit, CPU-only — the axis every tenant shares).
QOS_POOL = dict(n_bf2=3, n_bf1=1, n_pensando=2)
QOS_CROWD = "t-fw"
QOS_SURGE = 8.0

BARS = {"pooled_vs_standalone": 2.0, "pooled_vs_microservice": 1.2}


def run(emit=print, fast: bool = False, seed: int = 0,
        scenario: str = "full", obs_dir=None) -> dict:
    if scenario == "churn":
        res = {"defrag": run_defrag(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["defrag"]["pass"]
        return res
    if scenario == "flashcrowd":
        res = {"qos": run_qos(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["qos"]["pass"]
        return res
    if scenario == "adversarial":
        res = {"adversarial_churn": run_adversarial(emit=emit, fast=fast,
                                                    seed=seed)}
        res["pass"] = res["adversarial_churn"]["pass"]
        return res
    if scenario == "chaos":
        res = {"chaos": run_chaos(emit=emit, fast=fast, seed=seed,
                                  obs_dir=obs_dir)}
        res["pass"] = res["chaos"]["pass"]
        return res
    cfg = RuntimeConfig() if not fast else RuntimeConfig(
        dataplane_every=0, max_sim_seqs=48)
    res = run_comparison(ticks=FAST_TICKS if fast else TICKS, cfg=cfg,
                         seed=seed)
    for mode in MODES:
        emit(row(f"service_eff_{mode}", 0,
                 f"{res['efficiency'][mode]:.3f}Gbps_per_unit"))
    for name, ratio in res["ratios"].items():
        emit(row(f"service_{name}", 0,
                 f"{ratio:.2f}x_bar{BARS[name]:.1f}x"))
    for scenario, rec in res["scenarios"].items():
        for mode in MODES:
            emit(row(f"service_slo_{scenario}_{mode}", 0,
                     f"pass={rec[mode]['slo_pass']}"))
        if "failover" in rec:
            fo = rec["failover"]
            emit(row(f"service_failover_{scenario}", 0,
                     f"nic={fo['failed_nic']}_alive={fo['tenants_alive_after']}"
                     f"_survived={fo['survived']}"))
    res["defrag"] = run_defrag(emit=emit, fast=fast, seed=seed)
    res["qos"] = run_qos(emit=emit, fast=fast, seed=seed)
    res["adversarial_churn"] = run_adversarial(emit=emit, fast=fast,
                                               seed=seed)
    res["chaos"] = run_chaos(emit=emit, fast=fast, seed=seed,
                             obs_dir=obs_dir)
    res["bars"] = BARS
    res["pass"] = check(res)
    return res


def _run_churn_arm(defrag_on: bool, ticks: int, cfg: RuntimeConfig,
                   seed: int) -> dict:
    """One arm of the defrag A/B: same mix, same seeded traffic; only the
    background re-placement loop differs."""
    cfg = dataclasses.replace(
        cfg, defrag_every=8 if defrag_on else 0, defrag_max_moves=2)
    mix = churn_tenant_mix(ticks=ticks)
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("churn", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()     # churn + migration must leave pool truth intact
    slo = rt.slo_report()
    # Score locality over the settled tail of the run — after both churn
    # waves have landed, where fragmentation (or its recovery) persists.
    loc = rt.telemetry.locality(from_tick=int(0.7 * ticks))
    return {
        "locality": loc,
        "slo": slo,
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
        "migrations": sum(1 for e in ctrl.events if e["event"] == "migrate"),
        "alive_tenants": len(rt.alive_tenants()),
    }


def run_defrag(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Churn-heavy locality decay and recovery (ISSUE 3 acceptance).

    The full run drives the fused data plane like every other full-mode
    scenario; ``--fast`` drops to the analytic model only."""
    ticks = CHURN_FAST_TICKS if fast else CHURN_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    off = _run_churn_arm(False, ticks, cfg, seed)
    on = _run_churn_arm(True, ticks, cfg, seed)
    # No-regression: every tenant that passed its SLO with defrag off must
    # still pass with defrag on.
    regressed = sorted(t for t, ok in off["slo_pass"].items()
                       if ok and not on["slo_pass"].get(t, False))
    rec = {
        # self-describing: this record can be merged into a JSON produced
        # by a different mode/seed (--scenario churn), so it carries its own
        # run metadata rather than inheriting the file's.
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "defrag_off": off,
        "defrag_on": on,
        "recovery": {
            "nics_used_mean_delta": (off["locality"]["nics_used_mean"]
                                     - on["locality"]["nics_used_mean"]),
            "hop_pairs_mean_delta": (off["locality"]["hop_pairs_mean"]
                                     - on["locality"]["hop_pairs_mean"]),
            "slo_regressions": regressed,
        },
    }
    rec["pass"] = (rec["recovery"]["nics_used_mean_delta"] > 0.0
                   and rec["recovery"]["hop_pairs_mean_delta"] > 0.0
                   and not regressed
                   and on["migrations"] > 0)
    emit(row("service_defrag_nics", 0,
             f"{off['locality']['nics_used_mean']:.2f}_to_"
             f"{on['locality']['nics_used_mean']:.2f}"))
    emit(row("service_defrag_hop_pairs", 0,
             f"{off['locality']['hop_pairs_mean']:.2f}_to_"
             f"{on['locality']['hop_pairs_mean']:.2f}"))
    emit(row("service_defrag_migrations", 0, f"{on['migrations']}moves"))
    emit(row("service_defrag", 0, f"pass={rec['pass']}"))
    return rec


def _qos_mix():
    """The evaluation mix without backup NICs (the QoS pool is smaller than
    the full rack, so the default bf1 backups may not exist)."""
    return [dataclasses.replace(s, backup_nic=None)
            for s in default_tenant_mix()]


def _run_flash_arm(governor_on: bool, ticks: int, cfg: RuntimeConfig,
                   seed: int) -> dict:
    """One arm of the QoS isolation A/B: same mix, same seeded flash-crowd
    traffic; only quota enforcement differs (ResourceGovernor enabled/off)."""
    mix = _qos_mix()
    ctrl = MeiliController(paper_cluster(**QOS_POOL),
                           governor=ResourceGovernor(enabled=governor_on))
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("flash_crowd", contracts(mix), seed=seed,
                       surge=QOS_SURGE, crowd=QOS_CROWD)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()     # quota enforcement must leave pool truth intact
    slo = rt.slo_report()
    crowd_ticks = rt.telemetry.series(QOS_CROWD)
    return {
        "slo": slo,
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
        "crowd_peak_granted_gbps": max(
            (t.granted_gbps for t in crowd_ticks), default=0.0),
        "crowd_peak_backlog_pkts": max(
            (t.backlog_pkts for t in crowd_ticks), default=0.0),
        "alive_tenants": len(rt.alive_tenants()),
    }


def run_qos(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Flash-crowd isolation A/B (ISSUE 4 acceptance): with the governor, a
    crowd tenant exceeding its quota queues behind its own deficit and
    degrades only itself; without it, the crowd's unguarded over-scaling
    strips the headroom ≥1 in-quota tenant needs and breaks its SLO."""
    ticks = QOS_FAST_TICKS if fast else QOS_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    on = _run_flash_arm(True, ticks, cfg, seed)
    off = _run_flash_arm(False, ticks, cfg, seed)
    innocents_on_ok = all(ok for t, ok in on["slo_pass"].items()
                          if t != QOS_CROWD)
    broken_off = sorted(t for t, ok in off["slo_pass"].items()
                        if t != QOS_CROWD and not ok)
    crowd_quota = contracts(_qos_mix())[QOS_CROWD]   # default quota = contract
    crowd_clamped = on["crowd_peak_granted_gbps"] <= crowd_quota + 1e-6
    rec = {
        # self-describing (mergeable into a JSON from another mode/seed).
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(QOS_POOL),
        "crowd": QOS_CROWD,
        "surge": QOS_SURGE,
        "governor_on": on,
        "governor_off": off,
        "isolation": {
            "innocents_within_slo_on": innocents_on_ok,
            "crowd_clamped_at_quota_on": crowd_clamped,
            "crowd_contained_on": not on["slo_pass"].get(QOS_CROWD, True),
            "innocents_broken_off": broken_off,
        },
    }
    # Pass: governor-on protects every in-quota tenant AND actually clamps
    # the crowd at its quota (its excess degrades only itself), while
    # governor-off demonstrably harms >= 1 innocent.
    rec["pass"] = bool(innocents_on_ok and crowd_clamped and broken_off)
    emit(row("service_qos_crowd_granted", 0,
             f"on{on['crowd_peak_granted_gbps']:.1f}Gbps_off"
             f"{off['crowd_peak_granted_gbps']:.1f}Gbps"))
    emit(row("service_qos_isolation_on", 0,
             f"innocents_ok={innocents_on_ok}"))
    emit(row("service_qos_isolation_off", 0,
             f"broken={len(broken_off)}:{','.join(broken_off) or 'none'}"))
    emit(row("service_qos", 0, f"pass={rec['pass']}"))
    return rec


def run_adversarial(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Adversarial churn (admission pressure at peak): the churning tenant
    mix under correlated near-contract load on the headroom-free QoS pool —
    wave-2 arrivals must be strictly admitted (or rejected) while the pool
    is as full as it gets, without harming anyone already admitted."""
    ticks = QOS_FAST_TICKS if fast else QOS_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    mix = [dataclasses.replace(s, backup_nic=None)
           for s in churn_tenant_mix(ticks=ticks)]
    ctrl = MeiliController(paper_cluster(**QOS_POOL),
                           governor=ResourceGovernor())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("adversarial_churn", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()
    slo = rt.slo_report()
    rec = {
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(QOS_POOL),
        "admitted": len(registry.admitted),
        "rejected": {t: r for t, r in registry.rejected.items()},
        "alive_tenants": len(rt.alive_tenants()),
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
    }
    # Pass: admission pressure was real (>=1 strict rejection), nobody
    # admitted was dropped, no admitted tenant lost its SLO, ledger exact.
    rec["pass"] = bool(rec["rejected"]
                       and rec["alive_tenants"] == rec["admitted"]
                       and all(rec["slo_pass"].values()))
    emit(row("service_adversarial_admissions", 0,
             f"admitted{rec['admitted']}_rejected{len(rec['rejected'])}"))
    emit(row("service_adversarial_churn", 0, f"pass={rec['pass']}"))
    return rec


def _chaos_mix():
    """The evaluation mix with backups remapped onto the chaos pool's two
    BF-1s (the default mix names bf1-2/bf1-3, which do not exist here)."""
    backups = ("bf1-0", "bf1-1")
    return [dataclasses.replace(s, backup_nic=backups[i % len(backups)])
            for i, s in enumerate(default_tenant_mix())]


def _chaos_plan(ticks: int, flap_nic: str, gray_nic: str) -> FaultPlan:
    """The compound fault sequence, identical on both arms: an early link
    flap, a silent gray degradation on a busy surviving-rack NIC, a crash
    landed inside a make-before-break migration window, a correlated rack
    outage taking half the pool, and a late repair wave that ends the
    incident — every NIC still down (the rack, the gray NIC, and whichever
    NIC the mid-migration crash hit) is replaced."""
    T = ticks
    return FaultPlan([
        FaultEvent(tick=max(2, int(0.11 * T)), kind=FLAP, nic=flap_nic,
                   duration_ticks=max(2, T // 16)),
        FaultEvent(tick=int(0.28 * T), kind=GRAY, nic=gray_nic,
                   fraction=0.25),
        FaultEvent(tick=int(0.44 * T), kind=MID_MIGRATION),
        FaultEvent(tick=int(0.55 * T), kind=RACK, rack=CHAOS_RACK),
        FaultEvent(tick=int(0.72 * T), kind=REVIVE),
    ])


def _run_chaos_arm(recovery_on: bool, ticks: int, seed: int,
                   obs_dir=None) -> dict:
    """One arm of the chaos A/B: same mix, same seeded traffic, same fault
    plan; only the recovery policy differs. ON = park + backoff re-admission
    + brownout partial grants + gray-failure detection; OFF = the legacy
    eviction-or-nothing baseline with no detection. With ``obs_dir`` set
    the arm's observability context (decision-audit trace + metrics) is
    dumped under ``<obs_dir>/chaos_{on,off}/`` as a run artifact."""
    cfg = RuntimeConfig(dataplane_every=0, max_sim_seqs=48,
                        gray_detect=recovery_on)
    mix = _chaos_mix()
    ctrl = MeiliController(paper_cluster(**CHAOS_POOL))
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("chaos", contracts(mix), seed=seed)
    rec_cfg = (RecoveryConfig(park=True, brownout=True, seed=seed)
               if recovery_on else RecoveryConfig(park=False, brownout=False))
    rt = ServiceRuntime(ctrl, registry, wl, cfg, recovery=rec_cfg)
    registry.admit_all()
    # Fault targets from the deterministic initial placement (identical on
    # both arms): the flap hits the busiest NIC overall, the gray failure
    # the busiest *surviving-rack* NIC that is not the flap target — so the
    # gray NIC carries tenants whose achieved throughput can betray it.
    usage: dict = {}
    for dep in ctrl.deployments.values():
        for n, nic_row in dep.allocation.A.items():
            usage[n] = usage.get(n, 0) + sum(nic_row.values())
    flap_nic = max(usage, key=lambda n: (usage[n], n))
    rack0 = [n for n in ctrl.pool.rack_members("rack0") if n != flap_nic]
    gray_nic = max(rack0, key=lambda n: (usage.get(n, 0), n))
    engine = ChaosEngine(_chaos_plan(ticks, flap_nic, gray_nic))
    rt.run(ticks, chaos=engine)
    ctrl.check_ledger()     # the sentinel also ran after every fault
    tele = rt.telemetry
    artifacts = None
    if obs_dir is not None:
        rt.obs.snapshot_compile_caches(planes=rt._planes.values())
        arm_dir = (pathlib.Path(obs_dir)
                   / ("chaos_on" if recovery_on else "chaos_off"))
        artifacts = rt.obs.dump(arm_dir)
    return {
        "recovery_on": recovery_on,
        "flap_nic": flap_nic,
        "gray_nic": gray_nic,
        "slo_ticks": tele.slo_tick_count(cfg.warmup_ticks),
        # Measured p99 (obs histogram over the run's sample stream) beside
        # the per-tick legacy estimator's max.
        "p99_measured_s_max": max(
            (t.p99_measured_s for t in tele.tenant_ticks), default=0.0),
        "p99_legacy_s_max": max(
            (t.p99_s for t in tele.tenant_ticks), default=0.0),
        "obs_artifacts": artifacts,
        "permanent_evictions": sorted(set(rt.recovery.evicted)),
        "parked_events": len(tele.faults("parked")),
        "readmissions": len(rt.recovery.readmissions),
        "still_parked": sorted(rt.recovery.parked),
        "mttr_ticks": rt.recovery.mean_time_to_recover(),
        "brownout_ticks": len({f.tick for f in tele.faults("degraded")}),
        "gray_probations": sorted({f.nic for f in
                                   tele.faults("gray_probation")}),
        "faults_injected": len(engine.fired),
        "alive_tenants": len(rt.alive_tenants()),
        "ledger_clean": True,
    }


def run_chaos(emit=print, fast: bool = False, seed: int = 0,
              obs_dir=None) -> dict:
    """Chaos fault-injection A/B (ISSUE 6 acceptance): under an identical
    compound fault plan, recovery-on must strictly dominate recovery-off —
    more tenant-ticks of SLO-compliant service, fewer permanent evictions
    (off must demonstrably lose >= 1 tenant for good), and a finite mean
    time-to-recover with every parked tenant re-admitted by run end. The
    invariant sentinel validates the ledger after every injected fault."""
    ticks = CHAOS_FAST_TICKS if fast else CHAOS_TICKS
    on = _run_chaos_arm(True, ticks, seed, obs_dir=obs_dir)
    off = _run_chaos_arm(False, ticks, seed, obs_dir=obs_dir)
    rec = {
        # self-describing (mergeable into a JSON from another mode/seed).
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(CHAOS_POOL),
        "recovery_on": on,
        "recovery_off": off,
        "dominance": {
            "slo_ticks_on_vs_off": [on["slo_ticks"], off["slo_ticks"]],
            "permanent_evictions_on_vs_off": [
                len(on["permanent_evictions"]),
                len(off["permanent_evictions"])],
            "all_parked_readmitted_on": not on["still_parked"],
            "mttr_ticks_on": on["mttr_ticks"],
        },
    }
    recovered = (not on["still_parked"]
                 and (on["parked_events"] == 0
                      or on["mttr_ticks"] is not None))
    rec["pass"] = bool(
        on["slo_ticks"] > off["slo_ticks"]
        and len(on["permanent_evictions"]) < len(off["permanent_evictions"])
        and off["permanent_evictions"]
        and recovered)
    emit(row("service_chaos_slo_ticks", 0,
             f"on{on['slo_ticks']}_off{off['slo_ticks']}"))
    emit(row("service_chaos_evictions", 0,
             f"on{len(on['permanent_evictions'])}"
             f"_off{len(off['permanent_evictions'])}"))
    emit(row("service_chaos_recovery", 0,
             f"parked{on['parked_events']}_readmitted{on['readmissions']}"
             f"_mttr{on['mttr_ticks'] if on['mttr_ticks'] is not None else 'na'}"))
    emit(row("service_chaos_brownout", 0,
             f"{on['brownout_ticks']}ticks_gray="
             f"{','.join(on['gray_probations']) or 'none'}"))
    emit(row("service_chaos_p99", 0,
             f"measured{on['p99_measured_s_max'] * 1e3:.1f}ms_legacy"
             f"{on['p99_legacy_s_max'] * 1e3:.1f}ms"))
    emit(row("service_chaos", 0, f"pass={rec['pass']}"))
    return rec


def check(res: dict) -> bool:
    ok = all(res["ratios"][k] >= bar for k, bar in BARS.items())
    for rec in res["scenarios"].values():
        ok = ok and all(rec[m]["slo_pass"] for m in MODES)
        if "failover" in rec:
            ok = ok and rec["failover"]["survived"]
    for extra in ("defrag", "qos", "adversarial_churn", "chaos"):
        if extra in res:
            ok = ok and res[extra]["pass"]
    return ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: fewer ticks, analytic model only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario",
                    choices=("full", "churn", "flashcrowd", "adversarial",
                             "chaos"),
                    default="full",
                    help="churn = only the defragmentation A/B "
                         "(make bench-defrag); flashcrowd = only the QoS "
                         "isolation A/B, adversarial = only the "
                         "admission-pressure run (make bench-qos)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_service.json)")
    ap.add_argument("--emit-obs", action="store_true",
                    help="write observability artifacts (decision-audit "
                         "trace + metrics + structured run log) under "
                         "--obs-dir")
    ap.add_argument("--obs-dir", default="obs_artifacts",
                    help="artifact directory for --emit-obs "
                         "(default: ./obs_artifacts)")
    args = ap.parse_args(argv)

    obs_dir = args.obs_dir if args.emit_obs else None
    logger = RunLogger("bench_service", out_dir=obs_dir)
    logger.note(fast=args.fast, seed=args.seed, scenario=args.scenario)
    logger.emit("name,us_per_call,derived")
    res = run(emit=logger.emit, fast=args.fast, seed=args.seed,
              scenario=args.scenario, obs_dir=obs_dir)
    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parent.parent / "BENCH_service.json")
    payload = {
        "benchmark": "meili-serve deployment-mode comparison",
        "fast": args.fast,
        "seed": args.seed,
        "scenario": args.scenario,
        "ticks": FAST_TICKS if args.fast else TICKS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **res,
    }
    partial_keys = {"churn": "defrag", "flashcrowd": "qos", "chaos": "chaos",
                    "adversarial": "adversarial_churn"}
    if args.scenario in partial_keys:
        # keep the full-comparison numbers already on disk; merge the new
        # partial record into the existing JSON instead of clobbering it
        key = partial_keys[args.scenario]
        if out.exists():
            try:
                prev = json.loads(out.read_text())
                prev.update({key: payload[key],
                             "timestamp": payload["timestamp"]})
                if "ratios" in prev:
                    prev["pass"] = check(prev)
                payload = prev
            except (ValueError, KeyError):
                pass
    out.write_text(json.dumps(payload, indent=2) + "\n")
    logger.close()
    print(f"# wrote {out}")
    if obs_dir is not None:
        print(f"# wrote obs artifacts under {obs_dir}")
    if not res["pass"]:
        raise SystemExit("service benchmark below acceptance bars")


if __name__ == "__main__":
    main()
