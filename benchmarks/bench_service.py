"""Meili-Serve resource-efficiency benchmark (ISSUE 2/3; paper §8, Fig 13).

Runs the default 6-tenant mix through the deployment-mode comparator
(pooled vs standalone vs microservice) under the bursty and diurnal
scenarios, with one NIC failure injected into the pooled bursty run, plus
the churn-heavy defragmentation A/B (ISSUE 3): the churning tenant mix under
the ``churn`` scenario with the background re-placement loop off vs on, same
seed and traffic. Writes ``BENCH_service.json`` with the efficiency ratios,
per-scenario per-tenant SLO compliance, the failover record, and the
locality-recovery record.

Headline acceptance bars (checked by ``main`` and surfaced in the JSON):
  pooled efficiency >= 2x standalone, >= 1.2x microservice, all tenant SLOs
  pass under both scenarios, the injected failure drops no tenant, and
  defrag-on uses fewer NICs with fewer hop-penalty pairs than defrag-off
  with no tenant SLO regression.

Run headlessly:   PYTHONPATH=src python -m benchmarks.bench_service
Smoke (CI) mode:  PYTHONPATH=src python -m benchmarks.bench_service --fast
Defrag A/B only:  PYTHONPATH=src python -m benchmarks.bench_service --scenario churn
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

from benchmarks.common import row
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.service.efficiency import MODES, run_comparison
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import TenantRegistry, churn_tenant_mix, contracts
from repro.service.workload import make_scenario

TICKS = 120
FAST_TICKS = 32
CHURN_TICKS = 96
CHURN_FAST_TICKS = 48

BARS = {"pooled_vs_standalone": 2.0, "pooled_vs_microservice": 1.2}


def run(emit=print, fast: bool = False, seed: int = 0,
        scenario: str = "full") -> dict:
    if scenario == "churn":
        res = {"defrag": run_defrag(emit=emit, fast=fast, seed=seed)}
        res["pass"] = res["defrag"]["pass"]
        return res
    cfg = RuntimeConfig() if not fast else RuntimeConfig(
        dataplane_every=0, max_sim_seqs=48)
    res = run_comparison(ticks=FAST_TICKS if fast else TICKS, cfg=cfg,
                         seed=seed)
    for mode in MODES:
        emit(row(f"service_eff_{mode}", 0,
                 f"{res['efficiency'][mode]:.3f}Gbps_per_unit"))
    for name, ratio in res["ratios"].items():
        emit(row(f"service_{name}", 0,
                 f"{ratio:.2f}x_bar{BARS[name]:.1f}x"))
    for scenario, rec in res["scenarios"].items():
        for mode in MODES:
            emit(row(f"service_slo_{scenario}_{mode}", 0,
                     f"pass={rec[mode]['slo_pass']}"))
        if "failover" in rec:
            fo = rec["failover"]
            emit(row(f"service_failover_{scenario}", 0,
                     f"nic={fo['failed_nic']}_alive={fo['tenants_alive_after']}"
                     f"_survived={fo['survived']}"))
    res["defrag"] = run_defrag(emit=emit, fast=fast, seed=seed)
    res["bars"] = BARS
    res["pass"] = check(res)
    return res


def _run_churn_arm(defrag_on: bool, ticks: int, cfg: RuntimeConfig,
                   seed: int) -> dict:
    """One arm of the defrag A/B: same mix, same seeded traffic; only the
    background re-placement loop differs."""
    cfg = dataclasses.replace(
        cfg, defrag_every=8 if defrag_on else 0, defrag_max_moves=2)
    mix = churn_tenant_mix(ticks=ticks)
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("churn", contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()     # churn + migration must leave pool truth intact
    slo = rt.slo_report()
    # Score locality over the settled tail of the run — after both churn
    # waves have landed, where fragmentation (or its recovery) persists.
    loc = rt.telemetry.locality(from_tick=int(0.7 * ticks))
    return {
        "locality": loc,
        "slo": slo,
        "slo_pass": {t: r["pass"] for t, r in slo.items()},
        "migrations": sum(1 for e in ctrl.events if e["event"] == "migrate"),
        "alive_tenants": len(rt.alive_tenants()),
    }


def run_defrag(emit=print, fast: bool = False, seed: int = 0) -> dict:
    """Churn-heavy locality decay and recovery (ISSUE 3 acceptance).

    The full run drives the fused data plane like every other full-mode
    scenario; ``--fast`` drops to the analytic model only."""
    ticks = CHURN_FAST_TICKS if fast else CHURN_TICKS
    cfg = (RuntimeConfig(dataplane_every=0, max_sim_seqs=48) if fast
           else RuntimeConfig())
    off = _run_churn_arm(False, ticks, cfg, seed)
    on = _run_churn_arm(True, ticks, cfg, seed)
    # No-regression: every tenant that passed its SLO with defrag off must
    # still pass with defrag on.
    regressed = sorted(t for t, ok in off["slo_pass"].items()
                       if ok and not on["slo_pass"].get(t, False))
    rec = {
        # self-describing: this record can be merged into a JSON produced
        # by a different mode/seed (--scenario churn), so it carries its own
        # run metadata rather than inheriting the file's.
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "defrag_off": off,
        "defrag_on": on,
        "recovery": {
            "nics_used_mean_delta": (off["locality"]["nics_used_mean"]
                                     - on["locality"]["nics_used_mean"]),
            "hop_pairs_mean_delta": (off["locality"]["hop_pairs_mean"]
                                     - on["locality"]["hop_pairs_mean"]),
            "slo_regressions": regressed,
        },
    }
    rec["pass"] = (rec["recovery"]["nics_used_mean_delta"] > 0.0
                   and rec["recovery"]["hop_pairs_mean_delta"] > 0.0
                   and not regressed
                   and on["migrations"] > 0)
    emit(row("service_defrag_nics", 0,
             f"{off['locality']['nics_used_mean']:.2f}_to_"
             f"{on['locality']['nics_used_mean']:.2f}"))
    emit(row("service_defrag_hop_pairs", 0,
             f"{off['locality']['hop_pairs_mean']:.2f}_to_"
             f"{on['locality']['hop_pairs_mean']:.2f}"))
    emit(row("service_defrag_migrations", 0, f"{on['migrations']}moves"))
    emit(row("service_defrag", 0, f"pass={rec['pass']}"))
    return rec


def check(res: dict) -> bool:
    ok = all(res["ratios"][k] >= bar for k, bar in BARS.items())
    for rec in res["scenarios"].values():
        ok = ok and all(rec[m]["slo_pass"] for m in MODES)
        if "failover" in rec:
            ok = ok and rec["failover"]["survived"]
    if "defrag" in res:
        ok = ok and res["defrag"]["pass"]
    return ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: fewer ticks, analytic model only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", choices=("full", "churn"), default="full",
                    help="churn = only the defragmentation A/B "
                         "(make bench-defrag)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_service.json)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    res = run(emit=print, fast=args.fast, seed=args.seed,
              scenario=args.scenario)
    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parent.parent / "BENCH_service.json")
    payload = {
        "benchmark": "meili-serve deployment-mode comparison",
        "fast": args.fast,
        "seed": args.seed,
        "scenario": args.scenario,
        "ticks": FAST_TICKS if args.fast else TICKS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **res,
    }
    if args.scenario == "churn":
        # keep the full-comparison numbers already on disk; merge the new
        # defrag record into the existing JSON instead of clobbering it
        if out.exists():
            try:
                prev = json.loads(out.read_text())
                prev.update({"defrag": payload["defrag"],
                             "timestamp": payload["timestamp"]})
                if "ratios" in prev:
                    prev["pass"] = check(prev)
                payload = prev
            except (ValueError, KeyError):
                pass
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")
    if not res["pass"]:
        raise SystemExit("service benchmark below acceptance bars")


if __name__ == "__main__":
    main()
